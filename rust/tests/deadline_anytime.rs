//! Deadline/anytime invariants across the whole solver battery.
//!
//! Two promises pin the cancellation layer:
//!
//! 1. **A deadline that never trips is free.** With a huge `deadline_ms`
//!    the cancel token is carried through every yield point but never
//!    fires, and results must be byte-identical to a deadline-free run —
//!    the checks may only cause early exit, never reorder or perturb the
//!    untripped search.
//! 2. **A deadline that trips immediately still answers.** With
//!    `deadline_ms=1` every family returns a *valid* best-effort schedule
//!    (never a hang, never a panic, never a spurious Unschedulable),
//!    marked `degraded`, and — since cancellation only truncates a search
//!    that takes running minima — its cost is bounded below by the
//!    unbounded optimum of the same space.

use kapla::arch::presets;
use kapla::coordinator::{run_job, Job, SolverKind};
use kapla::interlayer::dp::DpConfig;
use kapla::solvers::Objective;
use kapla::workloads::by_name;

fn battery() -> [SolverKind; 5] {
    [
        SolverKind::Baseline,
        SolverKind::DirectiveExhaustive,
        SolverKind::Random { p: 0.15, seed: 7 },
        SolverKind::Ml { seed: 7, rounds: 4, batch: 16 },
        SolverKind::Kapla,
    ]
}

fn job(net_name: &str, batch: u64, solver: SolverKind, deadline_ms: Option<u64>) -> Job {
    Job {
        net: by_name(net_name).unwrap(),
        batch,
        objective: Objective::Energy,
        solver,
        dp: DpConfig { max_rounds: 4, ..DpConfig::default() },
        deadline_ms,
    }
}

#[test]
fn huge_deadline_is_byte_identical_across_battery() {
    let arch = presets::bench_multi_node();
    for solver in battery() {
        let free = run_job(&arch, &job("mlp", 4, solver, None)).unwrap();
        let capped = run_job(&arch, &job("mlp", 4, solver, Some(600_000))).unwrap();
        assert_eq!(
            format!("{:?}", capped.schedule),
            format!("{:?}", free.schedule),
            "{solver:?}: untripped deadline must not perturb the schedule"
        );
        assert_eq!(
            capped.eval.energy.total(),
            free.eval.energy.total(),
            "{solver:?}: untripped deadline must not perturb the cost"
        );
        assert_eq!(capped.eval.latency_cycles, free.eval.latency_cycles, "{solver:?}");
        assert!(capped.degraded.is_none(), "{solver:?}: untripped run must not be degraded");
    }
}

#[test]
fn tiny_deadline_on_alexnet_degrades_but_always_answers() {
    let arch = presets::bench_multi_node();
    let layers = by_name("alexnet").unwrap().len();
    for solver in battery() {
        let r = run_job(&arch, &job("alexnet", 8, solver, Some(1)))
            .unwrap_or_else(|e| panic!("{solver:?}: tiny deadline must still answer, got {e}"));
        // The answer is a complete, valid schedule of the whole network.
        assert_eq!(r.schedule.num_layers(), layers, "{solver:?}");
        assert!(r.eval.energy.total() > 0.0, "{solver:?}");
        for (_, schemes) in &r.schedule.segments {
            for s in schemes {
                s.validate(&arch).unwrap();
            }
        }
        // ... and it is marked as best-effort with the deadline reason.
        let d = r.degraded.as_ref().unwrap_or_else(|| {
            panic!("{solver:?}: a 1 ms budget on alexnet must trip the deadline")
        });
        assert_eq!(d.reason, "deadline", "{solver:?}");
        assert!(d.best_effort, "{solver:?}");
        assert!(d.elapsed_ms > 0.0, "{solver:?}");
    }
}

#[test]
fn degraded_cost_is_bounded_below_by_unbounded_optimum() {
    // On a net where the exhaustive optimum is affordable, every family's
    // 1 ms best-effort schedule lives in the same directive space, so its
    // cost can never beat the unbounded exhaustive optimum. (This is the
    // sound version of "degradation only costs you quality": a truncated
    // search returns a valid point of the same space, and B's unbounded
    // DP is that space's global minimum.)
    let arch = presets::bench_multi_node();
    let optimum = run_job(&arch, &job("mlp", 4, SolverKind::Baseline, None))
        .unwrap()
        .eval
        .energy
        .total();
    for solver in battery() {
        let r = run_job(&arch, &job("mlp", 4, solver, Some(1))).unwrap();
        let cost = r.eval.energy.total();
        assert!(
            cost >= optimum * (1.0 - 1e-9),
            "{solver:?}: degraded cost {cost} beats the exhaustive optimum {optimum}"
        );
    }
}
