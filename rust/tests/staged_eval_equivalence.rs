//! The staged evaluation path (`sim::StagedEval` / the incremental
//! access-count calculus in `directives::scheme`) is an *optimization* of
//! the one-shot `sim::evaluate_layer`, never a semantic change: across
//! seeded random valid schemes on three architecture presets the two paths
//! must agree bit for bit, the `(part, gbuf)` prefix lower bound must stay
//! admissible against every completion (the property branch-and-bound
//! soundness rests on), and the pruned exhaustive search must return the
//! full scan's exact optimum.

use kapla::arch::{presets, ArchConfig};
use kapla::cost::{CostModel as _, TieredCost};
use kapla::directives::{LayerScheme, LevelBlock, LoopOrder};
use kapla::mapping::UnitMap;
use kapla::partition::enumerate_partitions;
use kapla::sim::{evaluate_layer, StagedEval};
use kapla::solvers::exhaustive::ExhaustiveIntra;
use kapla::solvers::space::{qty_candidates, visit_schemes, BnbCounters, PartOrder};
use kapla::solvers::{IntraCtx, IntraSolver as _, Objective};
use kapla::util::SplitMix64;
use kapla::workloads::nets;

/// The three presets the battery runs on: (arch, region, round batch).
fn presets_under_test() -> Vec<(&'static str, ArchConfig, (u64, u64), u64)> {
    vec![
        ("multi_node_eyeriss", presets::multi_node_eyeriss(), (4, 4), 8),
        ("bench_multi_node", presets::bench_multi_node(), (2, 2), 4),
        ("edge_tpu", presets::edge_tpu(), (1, 1), 1),
    ]
}

/// Draw one random valid scheme for `layer`, or `None` if the draw missed.
fn random_scheme(
    arch: &ArchConfig,
    layer: &kapla::workloads::Layer,
    region: (u64, u64),
    rb: u64,
    rng: &mut SplitMix64,
) -> Option<LayerScheme> {
    let parts = enumerate_partitions(layer, rb, region, true);
    if parts.is_empty() {
        return None;
    }
    let part = parts[rng.below(parts.len() as u64) as usize];
    let unit = UnitMap::build(arch, part.node_shape(layer, rb));
    let gqs = qty_candidates(unit.totals, unit.granule);
    let gq = gqs[rng.below(gqs.len() as u64) as usize];
    let rqs = qty_candidates(gq, unit.granule);
    let rq = rqs[rng.below(rqs.len() as u64) as usize];
    let orders = LoopOrder::all();
    let s = LayerScheme {
        part,
        unit,
        regf: LevelBlock { qty: rq, order: orders[rng.below(6) as usize] },
        gbuf: LevelBlock { qty: gq, order: orders[rng.below(6) as usize] },
    };
    s.validate(arch).ok().map(|_| s)
}

#[test]
fn staged_totals_are_bit_identical_to_one_shot() {
    let mut rng = SplitMix64::new(0x57A6ED);
    let net = nets::alexnet();
    let mnet = nets::mobilenet();
    let layers: Vec<&kapla::workloads::Layer> =
        net.layers.iter().take(6).chain(mnet.layers.iter().take(4)).collect();
    let mut checked = 0u32;
    for (name, arch, region, rb) in presets_under_test() {
        for layer in &layers {
            for _ in 0..24 {
                let Some(s) = random_scheme(&arch, layer, region, rb, &mut rng) else {
                    continue;
                };
                for ifm_on_chip in [false, true] {
                    let one_shot = evaluate_layer(&arch, &s, ifm_on_chip);
                    let staged = StagedEval::new(&arch, s.part, s.unit, ifm_on_chip)
                        .gbuf(s.gbuf.qty, s.gbuf.order)
                        .eval(s.regf.qty, s.regf.order);
                    // Bit-exact equality across every field — integer
                    // counts and f64 energy/latency alike.
                    assert_eq!(staged.access, one_shot.access, "{name}/{}", layer.name);
                    assert_eq!(staged.energy, one_shot.energy, "{name}/{}", layer.name);
                    assert_eq!(
                        staged.latency_cycles, one_shot.latency_cycles,
                        "{name}/{}",
                        layer.name
                    );
                    assert_eq!(staged.compute_cycles, one_shot.compute_cycles);
                    assert_eq!(staged.dram_cycles, one_shot.dram_cycles);
                }
                checked += 1;
            }
        }
    }
    assert!(checked > 100, "property needs coverage, only {checked} schemes drawn");
}

#[test]
fn prefix_bound_admissible_for_every_completion() {
    // The estimate <= detailed admissibility property, extended to
    // enumeration prefixes: bound_prefix(part, gq) never exceeds the
    // detailed evaluation of ANY (go, rq, ro) completion, in energy or
    // latency. This is exactly the soundness condition of the B&B pruning.
    let mut rng = SplitMix64::new(0xB0B0);
    let net = nets::alexnet();
    let model = TieredCost::fresh();
    let mut checked = 0u32;
    for (name, arch, region, rb) in presets_under_test() {
        for layer in net.layers.iter().take(5) {
            for _ in 0..12 {
                let Some(s) = random_scheme(&arch, layer, region, rb, &mut rng) else {
                    continue;
                };
                for ifm_on_chip in [false, true] {
                    let staged = model
                        .staged(&arch, &s.part, &s.unit, ifm_on_chip)
                        .expect("tiered model opts into staging");
                    let bound = model.bound_prefix(&staged, s.gbuf.qty);
                    let ev = model.evaluate(&arch, &s, ifm_on_chip);
                    assert!(
                        bound.energy_pj <= ev.energy_pj + 1e-9,
                        "{name}/{}: energy bound {} > evaluation {}",
                        layer.name,
                        bound.energy_pj,
                        ev.energy_pj
                    );
                    assert!(
                        bound.latency_cycles <= ev.latency_cycles + 1e-9,
                        "{name}/{}: latency bound {} > evaluation {}",
                        layer.name,
                        bound.latency_cycles,
                        ev.latency_cycles
                    );
                }
                checked += 1;
            }
        }
    }
    assert!(checked > 50, "property needs coverage, only {checked} prefixes drawn");
}

#[test]
fn pruned_exhaustive_equals_full_scan_on_zoo_layers() {
    // Two zoo layers, both objectives: the branch-and-bound exhaustive
    // solver must return the byte-identical first-minimum scheme of a
    // plain full scan, while actually pruning subtrees.
    let arch = presets::bench_multi_node();
    let anet = nets::alexnet();
    let mnet = nets::mlp();
    let layers = [&anet.layers[2], &mnet.layers[0]];
    for objective in [Objective::Energy, Objective::Latency] {
        for layer in layers {
            let ctx = IntraCtx { region: (2, 2), rb: 4, ifm_on_chip: false, objective };
            // Full scan: one-shot evaluation of every candidate, first
            // minimum wins (the pre-staged solver semantics).
            let mut full: Option<(f64, LayerScheme)> = None;
            visit_schemes(&arch, layer, ctx.region, ctx.rb, true, |s| {
                let ev = evaluate_layer(&arch, s, ctx.ifm_on_chip);
                let c = match objective {
                    Objective::Energy => ev.energy.total(),
                    Objective::Latency => ev.latency_cycles,
                };
                if full.as_ref().map(|(b, _)| c < *b).unwrap_or(true) {
                    full = Some((c, *s));
                }
                true
            });
            let (full_cost, full_scheme) = full.expect("space non-empty");

            let counters = BnbCounters::new();
            // Enum order: this test pins byte-identity against the naive
            // enumeration-order scan, so the first-minimum identity matters.
            let solver = ExhaustiveIntra {
                with_sharing: true,
                stats: Some(&counters),
                part_floor: true,
                part_order: PartOrder::Enum,
                cancel: None,
            };
            let pruned = solver.solve(&arch, layer, &ctx, &TieredCost::fresh()).unwrap();
            assert_eq!(
                format!("{full_scheme:?}"),
                format!("{pruned:?}"),
                "{}/{objective:?}: optimum scheme changed",
                layer.name
            );
            let ev = evaluate_layer(&arch, &pruned, ctx.ifm_on_chip);
            let pruned_cost = match objective {
                Objective::Energy => ev.energy.total(),
                Objective::Latency => ev.latency_cycles,
            };
            assert_eq!(full_cost, pruned_cost, "{}/{objective:?}", layer.name);

            let st = counters.snapshot();
            assert!(st.schemes_visited > 0);
            assert!(
                st.prefixes_pruned > 0,
                "{}/{objective:?}: expected subtree pruning (visited {} prefixes, {} bounds)",
                layer.name,
                st.prefixes_visited,
                st.bound_evals
            );
            assert!(st.parts_visited > 0, "{}/{objective:?}", layer.name);

            // The partition-level floor is exact too: disabling it returns
            // the byte-identical scheme (only the work differs).
            let off = ExhaustiveIntra {
                with_sharing: true,
                stats: None,
                part_floor: false,
                part_order: PartOrder::Enum,
                cancel: None,
            }
                .solve(&arch, layer, &ctx, &TieredCost::fresh())
                .unwrap();
            assert_eq!(
                format!("{off:?}"),
                format!("{pruned:?}"),
                "{}/{objective:?}: part_floor=off changed the optimum",
                layer.name
            );
        }
    }
}
