//! Property tests for the bounded cross-job `cost::SessionCache` (seeded
//! SplitMix64 op-sequence generator stands in for proptest, which is not
//! in the offline registry). The invariants:
//!
//! 1. the entry budget is a hard ceiling after *any* operation sequence;
//! 2. every lookup — hit, miss, or post-eviction recompute — returns
//!    exactly what a fresh `sim::evaluate_layer` call returns;
//! 3. entries from different arch fingerprints never alias (the same
//!    scheme under two hardware configs yields each config's own result).
//!
//! Plus the `cache_stress` target CI drives with a tiny
//! `KAPLA_CACHE_BUDGET` to force eviction churn through a real solver run.

use kapla::arch::{presets, ArchConfig};
use kapla::coordinator::{run_job, run_job_with, Job, SolverKind};
use kapla::cost::{CacheBudget, EvalCache as _, SessionCache};
use kapla::directives::{Grp, LevelBlock, LayerScheme, LoopOrder, Qty};
use kapla::interlayer::dp::DpConfig;
use kapla::mapping::UnitMap;
use kapla::partition::PartitionScheme;
use kapla::solvers::Objective;
use kapla::util::SplitMix64;
use kapla::workloads::{nets, Layer};

/// A structurally valid scheme keyed by (k, gq): enough distinct keys to
/// stress every shard without touching solver machinery.
fn scheme(arch: &ArchConfig, k: u64, gq: u64) -> LayerScheme {
    let l = Layer::conv("c", 16, k, 14, 3, 1);
    let part = PartitionScheme::single();
    let unit = UnitMap::build(arch, part.node_shape(&l, 4));
    LayerScheme {
        part,
        unit,
        regf: LevelBlock { qty: Qty::new(1, 2, 2), order: LoopOrder([Grp::B, Grp::K, Grp::C]) },
        gbuf: LevelBlock { qty: Qty::new(1, gq, gq), order: LoopOrder([Grp::B, Grp::C, Grp::K]) },
    }
}

fn prop_archs() -> [ArchConfig; 2] {
    [
        presets::eyeriss_like((4, 4), (8, 8), 64, 32 * 1024),
        presets::eyeriss_like((4, 4), (8, 8), 64, 64 * 1024),
    ]
}

#[test]
fn random_op_sequences_respect_budget_and_purity() {
    let archs = prop_archs();
    for (seed, budget) in [(1u64, 1usize), (2, 3), (3, 8), (4, 32), (5, usize::MAX)] {
        let mut rng = SplitMix64::new(seed);
        let sc = SessionCache::new(CacheBudget { max_entries: budget });
        for op in 0..400 {
            let arch = &archs[rng.below(2) as usize];
            let k = 8 + 8 * rng.below(8);
            let gq = [2u64, 4, 8][rng.below(3) as usize];
            let flag = rng.chance(0.5);
            let s = scheme(arch, k, gq);
            let got = sc.evaluate_layer(arch, &s, flag);
            let want = kapla::sim::evaluate_layer(arch, &s, flag);
            assert_eq!(
                format!("{got:?}"),
                format!("{want:?}"),
                "op {op} (budget {budget}): cached result must equal a fresh simulation"
            );
            if budget != usize::MAX {
                assert!(
                    sc.len() <= budget,
                    "op {op}: {} entries exceed budget {budget}",
                    sc.len()
                );
            }
            let st = sc.stats();
            assert!(st.hits <= st.lookups);
            assert_eq!(st.entries, sc.len());
        }
        let st = sc.stats();
        assert_eq!(st.lookups, 400);
        if budget <= 8 {
            assert!(st.evictions > 0, "budget {budget} must have churned by op 400");
        }
    }
}

#[test]
fn hits_always_equal_fresh_simulation() {
    let archs = prop_archs();
    let sc = SessionCache::unbounded();
    for arch in &archs {
        for k in [8u64, 16, 32, 64] {
            let s = scheme(arch, k, 4);
            let cold = sc.evaluate_layer(arch, &s, false);
            let before = sc.hits();
            let hit = sc.evaluate_layer(arch, &s, false);
            assert_eq!(sc.hits(), before + 1, "second lookup must hit");
            let fresh = kapla::sim::evaluate_layer(arch, &s, false);
            assert_eq!(format!("{hit:?}"), format!("{fresh:?}"));
            assert_eq!(format!("{cold:?}"), format!("{fresh:?}"));
        }
    }
}

#[test]
fn arch_fingerprints_never_alias_even_under_churn() {
    let archs = prop_archs();
    let sc = SessionCache::new(CacheBudget::entries(4));
    for round in 0..3 {
        for k in [8u64, 16, 24, 32, 40] {
            let s = scheme(&archs[0], k, 4);
            let e1 = sc.evaluate_layer(&archs[0], &s, false);
            let e2 = sc.evaluate_layer(&archs[1], &s, false);
            // Larger GBUF costs more per access; an aliased entry would
            // report the wrong arch's number.
            assert!(
                e2.energy.gbuf_pj > e1.energy.gbuf_pj,
                "round {round} k {k}: arch entries aliased"
            );
            assert!(sc.len() <= 4);
        }
    }
}

#[test]
fn concurrent_churn_stays_correct_and_bounded() {
    let archs = prop_archs();
    let sc = SessionCache::new(CacheBudget::entries(6));
    let keys: Vec<(usize, u64, u64, bool)> = {
        let mut rng = SplitMix64::new(99);
        (0..64)
            .map(|_| {
                (
                    rng.below(2) as usize,
                    8 + 8 * rng.below(8),
                    [2u64, 4, 8][rng.below(3) as usize],
                    rng.chance(0.5),
                )
            })
            .collect()
    };
    let totals = kapla::util::par_map(&keys, 4, |&(ai, k, gq, flag)| {
        let arch = &archs[ai];
        let s = scheme(arch, k, gq);
        sc.evaluate_layer(arch, &s, flag).energy.total()
    });
    for (&(ai, k, gq, flag), got) in keys.iter().zip(&totals) {
        let arch = &archs[ai];
        let want = kapla::sim::evaluate_layer(arch, &scheme(arch, k, gq), flag).energy.total();
        assert_eq!(*got, want);
    }
    assert!(sc.len() <= 6, "concurrent inserts exceeded the budget: {}", sc.len());
    assert_eq!(sc.lookups(), 64);
}

/// CI drives this with `KAPLA_CACHE_BUDGET=16` so a real solver run churns
/// the cache hard; the schedule must not care.
#[test]
fn cache_stress() {
    let budget: usize = std::env::var("KAPLA_CACHE_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let arch = presets::bench_multi_node();
    let job = Job {
        net: nets::mlp(),
        batch: 8,
        objective: Objective::Energy,
        solver: SolverKind::Kapla,
        dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
        deadline_ms: None,
    };
    let golden = run_job(&arch, &job).unwrap();

    let session = SessionCache::new(CacheBudget::entries(budget));
    for pass in 0..2 {
        let r = run_job_with(&arch, &job, &session).unwrap();
        assert_eq!(
            format!("{:?}", r.schedule),
            format!("{:?}", golden.schedule),
            "pass {pass}: eviction churn changed the schedule"
        );
        assert_eq!(r.eval.energy.total(), golden.eval.energy.total());
        assert!(session.len() <= budget, "budget breached: {}", session.len());
    }
    let st = session.stats();
    assert!(
        st.evictions > 0,
        "budget {budget} should force eviction churn ({} lookups, {} entries)",
        st.lookups,
        st.entries
    );
}
