//! First-class backward layers: conservation properties of the training
//! graphs (per-layer back-activation / back-weight MAC counts equal the
//! forward layer's; tensor word totals consistent under the C/K role
//! swap), and equivalence of the new `ConvBwAct` kind with the historical
//! dims-swapped-`Conv` modeling where their roles coincide.

use kapla::arch::{presets, PeDataflow};
use kapla::directives::{LayerScheme, LevelBlock, LoopOrder, Qty};
use kapla::mapping::{LayerShape, UnitMap};
use kapla::partition::enumerate_partitions;
use kapla::solvers::space::qty_candidates;
use kapla::workloads::{all_networks, training_graph, Layer, LayerKind};

/// Every weighted forward layer in the zoo gets @bd/@bw/@wu successors
/// whose MAC counts conserve the forward count exactly.
#[test]
fn backward_macs_conserve_forward_across_zoo() {
    for fwd in all_networks() {
        let t = training_graph(&fwd);
        for l in &fwd.layers {
            if !l.has_weights() {
                continue;
            }
            let bd = t
                .layers
                .iter()
                .find(|x| x.name == format!("{}@bd", l.name))
                .unwrap_or_else(|| panic!("{}: missing {}@bd", t.name, l.name));
            let bw = t
                .layers
                .iter()
                .find(|x| x.name == format!("{}@bw", l.name))
                .unwrap_or_else(|| panic!("{}: missing {}@bw", t.name, l.name));
            assert!(
                t.layers.iter().any(|x| x.name == format!("{}@wu", l.name)),
                "{}: missing {}@wu",
                t.name,
                l.name
            );
            for n in [1u64, 16] {
                assert_eq!(bd.macs(n), l.macs(n), "{}: {}@bd macs", t.name, l.name);
                assert_eq!(bw.macs(n), l.macs(n), "{}: {}@bw macs", t.name, l.name);
            }
        }
    }
}

/// The back-activation layer reads dY and writes dX: its input/output
/// volumes are the forward layer's output/input volumes, and its filter
/// tensor is the same (transposed) weight tensor.
#[test]
fn backward_volumes_swap_roles_across_zoo() {
    for fwd in all_networks() {
        let t = training_graph(&fwd);
        for l in &fwd.layers {
            if !l.has_weights() {
                continue;
            }
            let bd = t.layers.iter().find(|x| x.name == format!("{}@bd", l.name)).unwrap();
            assert_eq!(bd.ifm_elems(16), l.ofm_elems(16), "{}: {}@bd reads dY", t.name, l.name);
            assert_eq!(bd.ofm_elems(16), l.ifm_elems(16), "{}: {}@bd writes dX", t.name, l.name);
            assert_eq!(bd.weight_elems(), l.weight_elems(), "{}: {}@bd filters", t.name, l.name);
        }
    }
}

/// Training graphs emit the dedicated backward kinds, not dims-swapped
/// forward kinds.
#[test]
fn training_graphs_use_first_class_kinds() {
    for fwd in all_networks() {
        let t = training_graph(&fwd);
        for (i, l) in t.layers.iter().enumerate() {
            if l.name.ends_with("@bd") {
                let base = &t.layers[..i]
                    .iter()
                    .find(|x| format!("{}@bd", x.name) == l.name)
                    .unwrap()
                    .kind;
                let want = if *base == LayerKind::DWConv {
                    LayerKind::DWConvBwAct
                } else {
                    LayerKind::ConvBwAct
                };
                assert_eq!(l.kind, want, "{}: {}", t.name, l.name);
            }
            if l.name.ends_with("@bw") {
                assert_eq!(l.kind, LayerKind::ConvBwWeight, "{}: {}", t.name, l.name);
            }
            if l.name.ends_with("@wu") {
                assert!(l.no_batch, "{}: {}", t.name, l.name);
            }
        }
    }
}

/// Under row-stationary (full fmap planes GBUF-resident), the node-scope
/// tensor word counts of a back-activation layer at full blocks are the
/// forward layer's with ifm/ofm swapped; weight words match under both
/// templates.
#[test]
fn node_word_totals_consistent_under_role_swap() {
    let layers = [
        Layer::conv("c", 24, 48, 14, 3, 1),
        Layer::conv("cs", 16, 32, 14, 3, 2),
        Layer::conv("pw", 64, 96, 7, 1, 1),
        Layer::fc("f", 256, 128),
    ];
    for l in &layers {
        let bd = Layer {
            name: format!("{}@bd", l.name),
            kind: LayerKind::ConvBwAct,
            c: l.k,
            k: l.c,
            xo: l.xi(),
            yo: l.yi(),
            r: l.r,
            s: l.s,
            stride: l.stride,
            no_batch: false,
        };
        let n = 4;
        let fsh = LayerShape::full(l, n);
        let bsh = LayerShape::full(&bd, n);
        let fq = Qty::new(n, l.c, l.k);
        let bq = Qty::new(n, bd.c, bd.k);

        let rs = presets::multi_node_eyeriss();
        assert_eq!(rs.pe_dataflow, PeDataflow::RowStationary);
        let mf = UnitMap::build(&rs, fsh);
        let mb = UnitMap::build(&rs, bsh);
        assert_eq!(mb.ifm_node_words(bq), mf.ofm_node_words(fq), "{}: ifm<-ofm", l.name);
        assert_eq!(mb.ofm_node_words(bq), mf.ifm_node_words(fq), "{}: ofm<-ifm", l.name);
        assert_eq!(mb.wgt_node_words(bq), mf.wgt_node_words(fq), "{}: wgt", l.name);

        let sys = presets::edge_tpu();
        assert_eq!(sys.pe_dataflow, PeDataflow::Systolic);
        let sf = UnitMap::build(&sys, fsh);
        let sb = UnitMap::build(&sys, bsh);
        assert_eq!(sb.wgt_node_words(bq), sf.wgt_node_words(fq), "{}: sys wgt", l.name);
        assert_eq!(sb.shape.macs(), sf.shape.macs(), "{}: macs", l.name);
    }
}

/// Where the roles coincide — stride 1 and a 1x1 filter, so the transposed
/// conv *is* a plain conv with C/K swapped — the new `ConvBwAct` kind must
/// produce byte-identical access counts, footprints and validity to the
/// historical dims-swapped-`Conv` modeling, under both array mappings.
#[test]
fn bwact_equals_dims_swapped_conv_where_roles_coincide() {
    // pointwise conv and FC: r = s = stride = 1.
    let cases = [Layer::conv("pw2", 96, 64, 14, 1, 1), Layer::fc("fc1", 512, 128)];
    for arch in [presets::bench_multi_node(), presets::edge_tpu()] {
        for l in &cases {
            let old = Layer {
                name: format!("{}@bd", l.name),
                kind: LayerKind::Conv,
                c: l.k,
                k: l.c,
                xo: l.xi(),
                yo: l.yi(),
                r: 1,
                s: 1,
                stride: 1,
                no_batch: false,
            };
            let mut new = old.clone();
            new.kind = LayerKind::ConvBwAct;
            new.validate().unwrap();
            let rb = 4;
            let mut compared = 0;
            for part in enumerate_partitions(&old, rb, (2, 2), true) {
                let uo = UnitMap::build(&arch, part.node_shape(&old, rb));
                let un = UnitMap::build(&arch, part.node_shape(&new, rb));
                assert_eq!(uo.totals, un.totals);
                assert_eq!(uo.granule, un.granule);
                for gq in qty_candidates(uo.totals, uo.granule).into_iter().step_by(3) {
                    let rq = uo.align_block(Qty::new(1, gq.c.min(2), gq.k.min(3)));
                    let order = LoopOrder::all()[1];
                    let mk = |unit| LayerScheme {
                        part,
                        unit,
                        regf: LevelBlock { qty: rq, order },
                        gbuf: LevelBlock { qty: gq, order },
                    };
                    let so = mk(uo);
                    let sn = mk(un);
                    assert_eq!(so.gbuf_words_per_node(), sn.gbuf_words_per_node());
                    assert_eq!(so.regf_words_per_pe(), sn.regf_words_per_pe());
                    assert_eq!(
                        so.validate(&arch).is_ok(),
                        sn.validate(&arch).is_ok(),
                        "{}: validity diverged",
                        l.name
                    );
                    if so.validate(&arch).is_err() {
                        continue;
                    }
                    for on_chip in [false, true] {
                        let ao = so.access_counts(on_chip);
                        let an = sn.access_counts(on_chip);
                        assert_eq!(ao.dram, an.dram, "{}: dram", l.name);
                        assert_eq!(ao.gbuf, an.gbuf, "{}: gbuf", l.name);
                        assert_eq!(ao.gbuf_regf_side, an.gbuf_regf_side, "{}: bus", l.name);
                        assert_eq!(ao.regf, an.regf, "{}: regf", l.name);
                        assert_eq!(ao.macs, an.macs, "{}: macs", l.name);
                        assert!((ao.noc_word_hops - an.noc_word_hops).abs() < 1e-9);
                    }
                    compared += 1;
                }
            }
            assert!(compared > 0, "{}: no schemes compared", l.name);
        }
    }
}

/// Depthwise back-activation keeps the depthwise partition constraints:
/// channels split through pk only, pc stays 1.
#[test]
fn dwconv_bwact_partition_constraints() {
    let fwd = Layer::dwconv("dw", 32, 28, 3, 2);
    let bd = Layer {
        name: "dw@bd".into(),
        kind: LayerKind::DWConvBwAct,
        c: fwd.c,
        k: fwd.c,
        xo: fwd.xi(),
        yo: fwd.yi(),
        r: fwd.r,
        s: fwd.s,
        stride: fwd.stride,
        no_batch: false,
    };
    let parts = enumerate_partitions(&bd, 8, (2, 2), true);
    assert!(!parts.is_empty());
    for p in &parts {
        assert_eq!(p.pc, 1, "depthwise bd must not split C");
        let sh = p.node_shape(&bd, 8);
        assert_eq!(sh.c, sh.k, "channel split applies to both views");
    }
}
