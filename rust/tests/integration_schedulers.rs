//! Integration tests: whole-stack scheduling runs across solvers,
//! architectures and workloads (workloads -> inter-layer DP -> intra-layer
//! solving -> directive calculus -> simulator), checking the cross-cutting
//! invariants the paper's evaluation relies on.

use kapla::arch::presets;
use kapla::coordinator::{run_job, Job, SolverKind};
use kapla::directives::emit::emit_layer;
use kapla::directives::parse::parse;
use kapla::interlayer::dp::DpConfig;
use kapla::sim::pipeline::evaluate_schedule;
use kapla::solvers::Objective;
use kapla::workloads::{by_name, nets, training_graph, Layer, Network};

fn tiny_net() -> Network {
    let mut n = Network::new("tiny", 8, 28, 28);
    n.chain(Layer::conv("c1", 8, 16, 28, 3, 1));
    n.chain(Layer::pool("p1", 16, 14, 2, 2));
    n.chain(Layer::conv("c2", 16, 32, 14, 3, 1));
    n.chain(Layer::fc("f1", 32 * 14 * 14, 64));
    n
}

fn job(net: Network, solver: SolverKind) -> Job {
    Job {
        net,
        batch: 8,
        objective: Objective::Energy,
        solver,
        dp: DpConfig { max_rounds: 8, ..DpConfig::default() },
        deadline_ms: None,
    }
}

#[test]
fn every_solver_schedules_tiny_net() {
    let arch = presets::bench_multi_node();
    for solver in [
        SolverKind::Baseline,
        SolverKind::DirectiveExhaustive,
        SolverKind::Random { p: 0.15, seed: 1 },
        SolverKind::Ml { seed: 1, rounds: 4, batch: 16 },
        SolverKind::Kapla,
    ] {
        let j = job(tiny_net(), solver);
        let r = run_job(&arch, &j).unwrap();
        assert_eq!(r.schedule.num_layers(), 4, "{solver:?}");
        assert!(r.eval.energy.total() > 0.0);
        // Every scheme in the schedule is valid.
        for (_, schemes) in &r.schedule.segments {
            for s in schemes {
                s.validate(&arch).unwrap();
            }
        }
    }
}

#[test]
fn kapla_quality_band_vs_exhaustive() {
    // The headline claim at network level: KAPLA within a tight band of
    // the exhaustive optimum (paper: +2.2% train / +7.7% infer; our
    // directive space lets K dip slightly below B).
    let arch = presets::bench_multi_node();
    let jb = job(tiny_net(), SolverKind::Baseline);
    let b = run_job(&arch, &jb).unwrap();
    let jk = job(tiny_net(), SolverKind::Kapla);
    let k = run_job(&arch, &jk).unwrap();
    let ratio = k.eval.energy.total() / b.eval.energy.total();
    assert!((0.7..=1.2).contains(&ratio), "K/B = {ratio:.3}");
    assert!(k.solve_s < b.solve_s, "K ({}) not faster than B ({})", k.solve_s, b.solve_s);
}

#[test]
fn random_and_ml_bounded_below_by_exhaustive() {
    let arch = presets::bench_multi_node();
    let jb = job(tiny_net(), SolverKind::Baseline);
    let b = run_job(&arch, &jb).unwrap();
    // R and M search subsets of B's space (same partitions, same blocks),
    // so they cannot beat it.
    for solver in
        [SolverKind::Random { p: 0.1, seed: 3 }, SolverKind::Ml { seed: 3, rounds: 4, batch: 16 }]
    {
        let j = job(tiny_net(), solver);
        let r = run_job(&arch, &j).unwrap();
        assert!(
            r.eval.energy.total() >= b.eval.energy.total() * 0.999,
            "{solver:?} beat exhaustive: {} vs {}",
            r.eval.energy.total(),
            b.eval.energy.total()
        );
    }
}

#[test]
fn deterministic_schedules() {
    let arch = presets::bench_multi_node();
    for solver in [SolverKind::Kapla, SolverKind::Random { p: 0.2, seed: 9 }] {
        let ja = job(tiny_net(), solver);
        let a = run_job(&arch, &ja).unwrap();
        let b = run_job(&arch, &ja).unwrap();
        assert_eq!(a.eval.energy.total(), b.eval.energy.total(), "{solver:?}");
        assert_eq!(a.schedule.segments.len(), b.schedule.segments.len());
    }
}

#[test]
fn emitted_directives_of_solved_schedule_roundtrip() {
    let arch = presets::bench_multi_node();
    let r = run_job(&arch, &job(tiny_net(), SolverKind::Kapla)).unwrap();
    let net = tiny_net();
    for (seg, schemes) in &r.schedule.segments {
        for (pos, s) in schemes.iter().enumerate() {
            let name = &net.layers[seg.layers[pos]].name;
            let text = emit_layer(name, s);
            let progs = parse(&text).unwrap_or_else(|e| panic!("{name}: {e}\n{text}"));
            assert_eq!(progs.len(), 1);
            assert_eq!(&progs[0].name, name);
            // Resident words visible by inspection match the scheme and
            // respect the hardware capacity.
            let words = progs[0].resident_words("GBUF").unwrap();
            assert_eq!(words, s.gbuf_words_per_node());
            assert!(words <= arch.gbuf_words());
        }
    }
}

#[test]
fn all_nets_schedule_with_kapla_on_paper_arch() {
    let arch = presets::multi_node_eyeriss();
    for net in nets::all_networks() {
        let j = Job {
            net: net.clone(),
            batch: 64,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig::default(),
            deadline_ms: None,
        };
        let r = run_job(&arch, &j).unwrap();
        assert_eq!(r.schedule.num_layers(), net.len(), "{}", net.name);
        // Re-evaluating the schedule reproduces the reported numbers.
        let re = evaluate_schedule(&arch, &net, &r.schedule);
        assert!((re.energy.total() - r.eval.energy.total()).abs() < 1e-6);
    }
}

#[test]
fn training_graphs_schedule_with_kapla() {
    let arch = presets::multi_node_eyeriss();
    for name in ["alexnet", "mlp", "mobilenet"] {
        let net = training_graph(&by_name(name).unwrap());
        let j = Job {
            net: net.clone(),
            batch: 64,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig::default(),
            deadline_ms: None,
        };
        let r = run_job(&arch, &j).unwrap();
        assert_eq!(r.schedule.num_layers(), net.len(), "{name}");
    }
}

#[test]
fn edge_arch_schedules_all_nets_batch1() {
    let arch = presets::edge_tpu();
    for net in nets::all_networks() {
        let j = Job {
            net: net.clone(),
            batch: 1,
            objective: Objective::Energy,
            solver: SolverKind::Kapla,
            dp: DpConfig::default(),
            deadline_ms: None,
        };
        let r = run_job(&arch, &j).unwrap();
        assert_eq!(r.schedule.num_layers(), net.len(), "{}", net.name);
        for (seg, _) in &r.schedule.segments {
            assert!(!seg.spatial, "single-node arch cannot pipeline");
        }
    }
}

#[test]
fn latency_objective_improves_latency() {
    let arch = presets::bench_multi_node();
    let je = job(tiny_net(), SolverKind::Kapla);
    let e = run_job(&arch, &je).unwrap();
    let mut jl = job(tiny_net(), SolverKind::Kapla);
    jl.objective = Objective::Latency;
    let l = run_job(&arch, &jl).unwrap();
    assert!(l.eval.latency_cycles <= e.eval.latency_cycles * 1.05);
}
