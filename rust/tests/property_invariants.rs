//! Property-based sweeps (seeded SplitMix64 stands in for proptest, which
//! is not in the offline registry): randomized layers, partitions and
//! blockings must uphold the core invariants of the directive calculus and
//! the solvers.

use kapla::arch::presets;
use kapla::directives::{LevelBlock, LayerScheme, LoopOrder, Qty};
use kapla::mapping::UnitMap;
use kapla::partition::{enumerate_partitions, PartitionScheme};
use kapla::sim::evaluate_layer;
use kapla::solvers::kapla::solve_intra;
use kapla::solvers::space::{qty_candidates, visit_schemes};
use kapla::solvers::{IntraCtx, Objective};
use kapla::util::SplitMix64;
use kapla::workloads::Layer;

/// Random but plausible conv/fc/dw layer.
fn random_layer(rng: &mut SplitMix64) -> Layer {
    let c = 1 + rng.below(96);
    let k = 1 + rng.below(128);
    let xo = 1 + rng.below(32);
    let r = *rng.choose(&[1u64, 3, 5, 7]);
    match rng.below(4) {
        0 => Layer::fc("f", c, k),
        1 => Layer::dwconv("d", c, xo.max(2), r, 1 + rng.below(2)),
        _ => Layer::conv("c", c, k, xo.max(r), r, 1 + rng.below(2)),
    }
}

fn random_scheme(rng: &mut SplitMix64, arch: &kapla::arch::ArchConfig, l: &Layer, rb: u64) -> Option<LayerScheme> {
    let parts = enumerate_partitions(l, rb, (2, 2), true);
    if parts.is_empty() {
        return None;
    }
    let part = *rng.choose(&parts);
    let unit = UnitMap::build(arch, part.node_shape(l, rb));
    let gqs = qty_candidates(unit.totals, unit.granule);
    let gq = *rng.choose(&gqs);
    let rqs = qty_candidates(gq, unit.granule);
    let rq = *rng.choose(&rqs);
    let s = LayerScheme {
        part,
        unit,
        regf: LevelBlock { qty: rq, order: *rng.choose(&LoopOrder::all()) },
        gbuf: LevelBlock { qty: gq, order: *rng.choose(&LoopOrder::all()) },
    };
    s.validate(arch).ok().map(|_| s)
}

#[test]
fn access_counts_at_least_compulsory() {
    // DRAM traffic of any valid scheme covers each tensor at least once
    // (per its replication/sharing structure).
    let arch = presets::bench_multi_node();
    let mut rng = SplitMix64::new(101);
    let mut checked = 0;
    while checked < 300 {
        let l = random_layer(&mut rng);
        let rb = 1 + rng.below(8);
        let Some(s) = random_scheme(&mut rng, &arch, &l, rb) else { continue };
        checked += 1;
        let a = s.access_counts(false);
        let ofm_floor = s.unit.ofm_node_words(s.unit.totals) * s.part.used_nodes()
            / s.part.ofm_reduction_for(l.kind).max(1);
        assert!(
            a.dram[1] >= ofm_floor,
            "{l:?}: ofm dram {} < floor {ofm_floor}",
            a.dram[1]
        );
        assert!(a.gbuf_total() >= a.dram_total(), "GBUF port sees all DRAM traffic");
        assert!(a.macs >= l.macs(rb), "macs under-counted");
    }
}

#[test]
fn macs_invariant_across_schemes() {
    // Blocking and ordering change traffic, never compute volume
    // (fragmentation may pad it upward via ceiling splits).
    let arch = presets::bench_multi_node();
    let mut rng = SplitMix64::new(202);
    for _ in 0..60 {
        let l = random_layer(&mut rng);
        let rb = 1 + rng.below(4);
        let mut macs = Vec::new();
        for _ in 0..8 {
            if let Some(s) = random_scheme(&mut rng, &arch, &l, rb) {
                if s.part.used_nodes() == 4 && s.part.pn * s.part.pk * s.part.pc == 4 {
                    macs.push(s.access_counts(false).macs);
                }
            }
        }
        // All full-channel/batch partitions of the same layer execute the
        // same MACs up to ceiling-split padding (< 2x).
        if let (Some(&min), Some(&max)) = (macs.iter().min(), macs.iter().max()) {
            assert!(max < 2 * min.max(1), "{l:?}: macs spread {min}..{max}");
        }
    }
}

#[test]
fn kapla_never_worse_than_every_random_scheme() {
    // Cost-descent must at least beat the average random valid scheme and
    // never lose to *all* of them.
    let arch = presets::bench_multi_node();
    let mut rng = SplitMix64::new(303);
    for _ in 0..25 {
        let l = random_layer(&mut rng);
        let ctx = IntraCtx { region: (2, 2), rb: 4, ifm_on_chip: false, objective: Objective::Energy };
        let Some(k) = solve_intra(&arch, &l, &ctx) else { continue };
        let ek = evaluate_layer(&arch, &k, false).energy.total();
        let mut beats = 0;
        let mut total = 0;
        for _ in 0..20 {
            if let Some(s) = random_scheme(&mut rng, &arch, &l, 4) {
                total += 1;
                if ek <= evaluate_layer(&arch, &s, false).energy.total() {
                    beats += 1;
                }
            }
        }
        if total >= 5 {
            assert!(
                beats * 2 >= total,
                "{l:?}: kapla beat only {beats}/{total} random schemes"
            );
        }
    }
}

#[test]
fn exhaustive_visit_only_yields_valid_schemes() {
    let arch = presets::bench_multi_node();
    let mut rng = SplitMix64::new(404);
    for _ in 0..10 {
        let l = random_layer(&mut rng);
        let mut n = 0;
        visit_schemes(&arch, &l, (2, 2), 2, true, |s| {
            s.validate(&arch).unwrap_or_else(|e| panic!("{l:?}: {e}"));
            n += 1;
            n < 5000
        });
        assert!(n > 0, "{l:?}: empty space");
    }
}

#[test]
fn partition_node_shapes_cover_layer() {
    // Ceil-split shapes must tile the full layer: shape * factor >= total.
    let mut rng = SplitMix64::new(505);
    for _ in 0..200 {
        let l = random_layer(&mut rng);
        for p in enumerate_partitions(&l, 8, (2, 2), false) {
            let s = p.node_shape(&l, 8);
            assert!(s.n * p.pn >= l.batch(8));
            assert!(s.k * p.pk >= l.k);
            assert!(s.xo * p.px >= l.xo);
            assert!(s.yo * p.py >= l.yo);
        }
    }
}

#[test]
fn descent_is_deterministic_and_capacity_safe() {
    let arch = presets::edge_tpu();
    let mut rng = SplitMix64::new(606);
    for _ in 0..40 {
        let l = random_layer(&mut rng);
        let ctx = IntraCtx { region: (1, 1), rb: 1, ifm_on_chip: false, objective: Objective::Energy };
        let a = solve_intra(&arch, &l, &ctx);
        let b = solve_intra(&arch, &l, &ctx);
        match (a, b) {
            (Some(x), Some(y)) => {
                assert_eq!(format!("{x:?}"), format!("{y:?}"), "{l:?}");
                assert!(x.regf_words_per_pe() <= arch.regf_words());
                assert!(x.gbuf_words_per_node() <= arch.gbuf_words());
            }
            (None, None) => {}
            _ => panic!("{l:?}: nondeterministic solvability"),
        }
    }
}

#[test]
fn single_partition_matches_full_shape() {
    let mut rng = SplitMix64::new(707);
    for _ in 0..100 {
        let l = random_layer(&mut rng);
        let p = PartitionScheme::single();
        let s = p.node_shape(&l, 16);
        assert_eq!(s.c, l.c);
        assert_eq!(s.k, l.k);
        assert_eq!(s.n, l.batch(16));
    }
}
