//! Chaos battery: the service's one availability promise — every admitted
//! request gets exactly one structured answer — exercised under injected
//! cost-model faults and expired deadlines, concurrently.
//!
//! The `chaos=seed:panic_permille:latency_us` request knob (gated on
//! `KAPLA_CHAOS=1`, set process-wide by these tests) wraps the tenant's
//! session in a [`kapla::cost::FaultInjector`]: seeded panics unwind
//! through the solver into the worker's `catch_unwind` and come back as
//! `"internal error: chaos: ..."`; injected latency pushes solves past
//! their `deadline_ms=` budgets and forces the anytime degraded path. A
//! request may therefore come back complete, degraded, or failed — but it
//! must always come back, and the service must keep serving afterwards.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use kapla::arch::presets;
use kapla::coordinator::transport::{self, ServiceConfig};

fn send(conn: &mut TcpStream, line: &str) {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
}

fn recv(reader: &mut BufReader<TcpStream>) -> String {
    let mut s = String::new();
    reader.read_line(&mut s).unwrap();
    assert!(s.ends_with('\n'), "truncated response: {s:?}");
    s.trim_end().to_string()
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

#[test]
fn chaos_battery_answers_every_admitted_request() {
    std::env::set_var("KAPLA_CHAOS", "1");
    let arch = presets::bench_multi_node();
    let h = transport::spawn(
        &arch,
        ServiceConfig { queue_depth: 32, workers: 3, ..Default::default() },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = h.tcp_addr().unwrap();

    // Three fault profiles, cycled per request: moderate panic rate, an
    // always-panicking model, and injected latency against a 1 ms budget.
    // Tenants are per-client so a panicked solve never shares state with
    // the final health probe.
    let base = "schedule mlp 8 kapla threads=1 max_rounds=4";
    let responses: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..3)
            .map(|client| {
                scope.spawn(move || {
                    let (mut conn, mut reader) = connect(addr);
                    let mut got = Vec::new();
                    for i in 0..4u64 {
                        let seed = client as u64 * 101 + i;
                        let line = match i % 3 {
                            0 => format!("{base} tenant=c{client} chaos={seed}:300:0"),
                            1 => format!("{base} tenant=c{client} chaos={seed}:1000:0"),
                            _ => format!(
                                "{base} tenant=c{client} chaos={seed}:0:500 deadline_ms=1"
                            ),
                        };
                        send(&mut conn, &line);
                        got.push(recv(&mut reader));
                    }
                    got
                })
            })
            .collect();
        handles.into_iter().flat_map(|t| t.join().unwrap()).collect()
    });

    // 100% of admitted requests answered, every answer structured.
    assert_eq!(responses.len(), 12);
    let mut oks = 0;
    let mut internal = 0;
    let mut deadline_errors = 0;
    for r in &responses {
        if r.contains("\"ok\":true") {
            oks += 1;
        } else if r.contains("internal error: chaos: injected cost-model fault") {
            internal += 1;
        } else if r.contains("deadline exceeded") {
            deadline_errors += 1;
        } else {
            panic!("unstructured response under chaos: {r}");
        }
    }
    assert_eq!(oks + internal + deadline_errors, responses.len());
    // The always-panic profile ran 4 times; its very first evaluate fires,
    // so panics demonstrably crossed the catch_unwind boundary.
    assert!(internal >= 4, "expected the permille=1000 profile to panic: {responses:?}");

    // The service survived: a fault-free request on a fresh connection
    // still returns a complete schedule, and metrics still answer.
    let (mut conn, mut reader) = connect(addr);
    send(&mut conn, base);
    let healthy = recv(&mut reader);
    assert!(healthy.contains("\"ok\":true"), "service did not survive chaos: {healthy}");
    send(&mut conn, "metrics");
    let m = recv(&mut reader);
    assert!(m.contains("\"requests\":"), "{m}");
    h.shutdown();
}

#[test]
fn deadline_under_service_is_hang_capped() {
    // An exhaustive solve of alexnet would run for minutes; a 200 ms
    // budget must bring back a best-effort answer promptly (the generous
    // cap below guards against a hang, not against slowness — CI runs
    // this as a named step precisely to catch a cancellation point
    // regressing into a blocking wait).
    let arch = presets::bench_multi_node();
    let h = transport::spawn(&arch, ServiceConfig::default(), "127.0.0.1:0").unwrap();
    let (mut conn, mut reader) = connect(h.tcp_addr().unwrap());

    let t = Instant::now();
    send(&mut conn, "schedule alexnet 8 b threads=1 max_rounds=4 max_seg_len=2 deadline_ms=200");
    let r = recv(&mut reader);
    let elapsed = t.elapsed();
    assert!(elapsed < Duration::from_secs(120), "deadline did not bound the solve: {elapsed:?}");
    assert!(r.contains("\"ok\":true"), "{r}");
    assert!(r.contains("\"degraded\":{"), "a 200 ms alexnet/b solve must be best-effort: {r}");
    assert!(r.contains("\"reason\":\"deadline\""), "{r}");
    assert!(r.contains("\"best_effort\":true"), "{r}");

    // The degraded answer is visible in the service metrics.
    send(&mut conn, "metrics");
    let m = recv(&mut reader);
    assert!(m.contains("\"degraded\":1"), "{m}");
    h.shutdown();
}
