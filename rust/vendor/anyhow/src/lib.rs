//! API-compatible stub of the `anyhow` crate (see Cargo.toml). Carries
//! real error values and messages — only the breadth of the upstream API
//! is reduced, not the semantics of what is implemented.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with context chaining via message wrapping.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(Box::new(MessageError(message.to_string())))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(Box::new(e))
    }
}

#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazily-built context to an error, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}
