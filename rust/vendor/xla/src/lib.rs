//! API stub of the xla_extension bindings used by `runtime::pjrt` (see
//! Cargo.toml). Type-checks the PJRT surface; every runtime entry point
//! reports that the real bindings are not vendored.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} requires the real vendored xla_extension bindings \
         (this tree ships an API stub so --features pjrt type-checks offline)"
    )))
}

/// Parsed HLO module (text interchange).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!("HloModuleProto::from_text_file({})", path.as_ref().display()))
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A PJRT client (CPU plugin in the real bindings).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu()")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile()")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with buffer-convertible arguments; returns per-device
    /// output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute()")
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync()")
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1()")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple()")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec()")
    }
}
