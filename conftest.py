import os
import sys

# Make `compile.*` importable when pytest runs from the repo root
# (the canonical capture command is `pytest python/tests/ -q`).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
